"""Expensive-objective evaluation: train a candidate, measure detection and
false-alarm rates (paper §VI: hard limits 90 % detection / 20 % false alarm).

Candidates are small 1D-CNNs (hwlib layers decoded from a genome) trained
with AdamW on the synthetic ECG dataset.  Quantization-aware training applies
the genome's fake-quant config so the expensive objectives reflect the
quantized model that will be deployed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.genome import Genome
from repro.core.objective_schema import Constraints
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.hwlib.layers import LayerSpec, apply_layer, init_layer
from repro.hwlib.quant import QuantConfig, fake_quant, quantize_layer_params
from repro.optim import adamw, apply_updates, clip_by_global_norm


@dataclasses.dataclass
class TrainResult:
    detection_rate: float
    false_alarm_rate: float
    val_loss: float
    steps: int

    def meets_constraints(self, det_min=None, fa_max=None) -> bool:
        """Paper's hard limits; accepts a
        :class:`~repro.core.objective_schema.Constraints` or the legacy
        ``(det_min, fa_max)`` float pair."""
        return Constraints.coerce(det_min, fa_max).ok(
            self.detection_rate, self.false_alarm_rate)


def init_candidate(rng: jax.Array, specs: Sequence[LayerSpec], in_ch: int = 2
                   ) -> List[Dict[str, Any]]:
    params = []
    c = in_ch
    keys = jax.random.split(rng, len(specs))
    for k, spec in zip(keys, specs):
        params.append(init_layer(k, spec, c))
        if spec.out_channels:  # convs and dense change the channel count
            c = spec.out_channels
    return params


def forward(params: Sequence[Dict[str, Any]], specs: Sequence[LayerSpec],
            x: jnp.ndarray, quant: QuantConfig | None = None,
            train: bool = False) -> jnp.ndarray:
    """Full candidate forward. x: (B, L, 2) -> logits (B, n_classes)."""
    h = x
    if quant is not None:
        h = fake_quant(h, quant.input_bits)
    for p, s in zip(params, specs):
        if quant is not None:
            p = quantize_layer_params(p, s, quant)
        h = apply_layer(p, s, h, train=train)
        if quant is not None and s.kind == "dwsep_conv":
            h = fake_quant(h, quant.act_bits)
    return h


def refresh_bn_pure(params: List[Dict[str, Any]],
                    specs: Sequence[LayerSpec], x: jnp.ndarray,
                    quant: QuantConfig | None = None) -> List[Dict[str, Any]]:
    """Traceable body of :func:`refresh_bn_stats` (no jit at this level, so
    the batched trainer can vmap it over a stacked candidate bucket)."""
    new_params = []
    h = x
    if quant is not None:
        h = fake_quant(h, quant.input_bits)
    for p, s in zip(params, specs):
        q = quantize_layer_params(p, s, quant) if quant is not None else p
        if s.kind == "dwsep_conv" and "bn_scale" in p:
            from repro.hwlib.layers import _depthwise_conv1d
            pre = jnp.einsum(
                "blc,cd->bld",
                _depthwise_conv1d(h, q["dw"], s.stride), q["pw"]) + q["b"]
            p = dict(p)
            p["bn_mean"] = jnp.mean(pre, axis=(0, 1))
            p["bn_var"] = jnp.var(pre, axis=(0, 1))
        new_params.append(p)
        q2 = dict(quantize_layer_params(p, s, quant)) if quant is not None else p
        h = apply_layer(q2, s, h, train=False)
        if quant is not None and s.kind == "dwsep_conv":
            h = fake_quant(h, quant.act_bits)
    return new_params


def refresh_bn_stats(params: List[Dict[str, Any]],
                     specs: Sequence[LayerSpec], x: jnp.ndarray,
                     quant: QuantConfig | None = None) -> List[Dict[str, Any]]:
    """BN re-estimation: recompute each BN layer's running stats from a
    calibration batch under the *current* weights (functionally — returns a
    new params list).  Standard practice in functional JAX training loops;
    the stats are what batchnorm-folding consumes at compile time."""

    @jax.jit
    def _refresh(params, x):
        return refresh_bn_pure(params, specs, x, quant)

    return _refresh(list(params), x)


def _loss_fn(params, specs, quant, x, y):
    logits = forward(params, specs, x, quant, train=True)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return nll


def train_step_pure(params, opt_state, x, y, *, specs, quant, opt):
    """One SGD step as a traceable function (shared by the scalar per-step
    jit below and the batched trainer's vmapped ``lax.scan`` body)."""
    loss, grads = jax.value_and_grad(_loss_fn)(params, specs, quant, x, y)
    grads, _ = clip_by_global_norm(grads, 1.0)
    updates, opt_state = opt.update(grads, opt_state, params)
    params = apply_updates(params, updates)
    return params, opt_state, loss


def make_train_step_indexed(specs: Sequence[LayerSpec],
                            quant: QuantConfig | None, opt):
    """Train step that gathers its minibatch on device from the staged
    dataset (``x_all``/``y_all`` live on device once; ``idx`` is one row of
    the presampled index matrix) — no per-step host→device batch copies."""
    @jax.jit
    def step(params, opt_state, x_all, y_all, idx):
        return train_step_pure(params, opt_state, x_all[idx], y_all[idx],
                               specs=specs, quant=quant, opt=opt)

    return step


def presample_indices(seed: int, n: int, steps: int, batch_size: int,
                      calib_size: int = 256
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """The full ``(steps, batch_size)`` minibatch index matrix plus the BN
    calibration indices, drawn from ``default_rng(seed)`` in the exact
    stream order of the historical per-step sampling loop (numpy fills a
    ``(steps, B)`` draw row-major, so one call == ``steps`` successive
    per-step calls).  Single source of truth for the scalar AND batched
    training paths — matched seeds therefore train on matched minibatches.
    """
    nrng = np.random.default_rng(seed)
    idx = nrng.integers(0, n, (steps, batch_size))
    calib = nrng.integers(0, n, min(calib_size, n))
    return idx, calib


def detection_rates(pred: np.ndarray, y: np.ndarray) -> Tuple[float, float]:
    """(detection_rate, false_alarm_rate) of hard predictions vs labels."""
    pos, neg = y == 1, y == 0
    det = float((pred[pos] == 1).mean()) if pos.any() else 0.0
    fa = float((pred[neg] == 1).mean()) if neg.any() else 1.0
    return det, fa


def evaluate(params, specs, quant, x: np.ndarray, y: np.ndarray,
             batch: int = 256) -> Tuple[float, float, float]:
    """(detection_rate, false_alarm_rate, mean_nll) on a dataset.

    NLL sums and argmax predictions accumulate on device; the host sees a
    single transfer at the end instead of a blocking ``float(...)`` sync per
    eval batch.
    """
    @jax.jit
    def fwd(xb, yb):
        logits = forward(params, specs, xb, quant, train=False)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, yb[:, None], axis=1).sum()
        return nll, jnp.argmax(logits, axis=-1)

    preds, nll_parts = [], []
    for i in range(0, len(x), batch):
        nll, pred = fwd(jnp.asarray(x[i:i + batch]),
                        jnp.asarray(y[i:i + batch]))
        nll_parts.append(nll)
        preds.append(pred)
    pred = np.asarray(jnp.concatenate(preds))
    nll_sum = float(jnp.sum(jnp.stack(nll_parts)))
    det, fa = detection_rates(pred, y)
    return det, fa, nll_sum / len(x)


def prep_inputs(x: np.ndarray, want_len: int) -> np.ndarray:
    """Subsample max-resolution records to a genome's input length (the
    decimation gene): strided view, no copy when already at length."""
    if x.shape[1] == want_len:
        return x
    stride = x.shape[1] // want_len
    return x[:, : want_len * stride : stride]


def train_candidate(
    genome: Genome,
    data_train: Tuple[np.ndarray, np.ndarray],
    data_val: Tuple[np.ndarray, np.ndarray],
    *,
    space: SearchSpace = DEFAULT_SPACE,
    steps: int = 300,
    batch_size: int = 64,
    lr: float = 3e-3,
    seed: int = 0,
    use_quant: bool = True,
) -> TrainResult:
    """Train one candidate and return the expensive objectives.

    The dataset arrives at max resolution (decimation 16); the genome's
    decimation gene subsamples further if it asks for a shorter input.

    The training set is staged on device once and the whole
    ``(steps, batch_size)`` minibatch index matrix is presampled up front
    (:func:`presample_indices` — the identical stream the historical
    per-step numpy sampling produced), so the step loop gathers minibatches
    on device instead of paying a numpy gather + host→device copy per step.
    """
    specs = genome.phenotype(space)
    quant = genome.quant(space) if use_quant else None
    want_len = genome.input_length(space)

    x_tr, y_tr = prep_inputs(data_train[0], want_len), data_train[1]
    x_va, y_va = prep_inputs(data_val[0], want_len), data_val[1]

    rng = jax.random.PRNGKey(seed)
    params = init_candidate(rng, specs)
    opt = adamw(lr, b1=0.9, b2=0.99, weight_decay=1e-4)
    opt_state = opt.init(params)
    step_fn = make_train_step_indexed(specs, quant, opt)

    n = len(x_tr)
    idx, calib_idx = presample_indices(seed, n, steps, batch_size)
    x_dev, y_dev = jnp.asarray(x_tr), jnp.asarray(y_tr)
    idx_dev = jnp.asarray(idx)
    for s in range(steps):
        params, opt_state, _ = step_fn(params, opt_state, x_dev, y_dev,
                                       idx_dev[s])
    # BN re-estimation on a calibration slice before deployment-mode eval
    params = refresh_bn_stats(params, specs, x_dev[jnp.asarray(calib_idx)],
                              quant)
    det, fa, nll = evaluate(params, specs, quant, x_va, y_va)
    return TrainResult(detection_rate=det, false_alarm_rate=fa,
                       val_loss=nll, steps=steps)
