"""HALF's cross-layer loop applied to TPU implementation parameters.

The paper's method: explore topology/implementation choices against CHEAP
analytic platform models (Eqs. 1-4), keep the Pareto frontier, spend
expensive evaluation only on frontier candidates.  Here the "topology" is a
fixed zoo config and the genome is the *implementation*: microbatch count,
causal q-blocking, MoE execution strategy, remat policy — the same knobs
the §Perf hillclimb tuned by hand.  The cheap objective is an analytic
three-term roofline (calibrated against the measured dry-run cells), and
"expensive evaluation" is an actual ``dryrun.run_cell`` compile.

``examples/codesign_tpu.py`` demonstrates that the analytic frontier
reproduces the hillclimb's adopted configuration for kimi-k2 without a
single compile — HALF's central claim (hardware-aware search finds the
hand-tuned point automatically), transplanted to the pod.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeCell
from repro.core.cost_backend import TPU_ROOFLINE
from repro.core.pareto import pareto_front


@dataclasses.dataclass(frozen=True)
class ImplGenome:
    """Implementation-layer genes (the TPU analogue of HALF's alpha/quant)."""

    microbatches: int = 1
    n_q_blocks: int = 8          # causal q-blocking factor (1 = off)
    moe_impl: str = "sort"       # sort | ep_a2a
    remat: str = "full"          # full | dots

    def short(self) -> str:
        return (f"mb{self.microbatches}-qb{self.n_q_blocks}-"
                f"{self.moe_impl}-{self.remat}")


SEARCH_SPACE = {
    "microbatches": (1, 2, 4, 8, 16),
    "n_q_blocks": (1, 4, 8, 16),
    "moe_impl": ("sort", "ep_a2a"),
    "remat": ("full", "dots"),
}


@dataclasses.dataclass
class CostEstimate:
    compute_s: float
    memory_s: float
    collective_s: float
    act_gib: float               # activation live-set per device

    def vector(self) -> np.ndarray:
        return np.asarray([self.compute_s, self.memory_s,
                           self.collective_s, self.act_gib])

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def estimate_train_cell(cfg: ModelConfig, cell: ShapeCell, g: ImplGenome,
                        mesh_shape: Dict[str, int]) -> CostEstimate:
    """Analytic three-term roofline for a train step under genome ``g``.

    Deliberately simple closed forms — the same altitude as the paper's
    Eqs. 1-4: good enough to RANK implementation points, cross-checked
    against the measured dry-run cells (test_tpu_codesign.py).
    """
    chips = int(np.prod(list(mesh_shape.values())))
    n_data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    n_model = mesh_shape.get("model", 1)
    tokens = cell.global_batch * cell.seq_len
    d, L = cfg.d_model, cfg.n_layers
    n_active = cfg.active_param_count()
    n_embed = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    n_body = max(n_active - n_embed, 1)

    # ---- compute ---------------------------------------------------------
    remat_mult = 8.0 / 6.0 if g.remat == "full" else 6.5 / 6.0
    param_flops = 6.0 * n_body * tokens * remat_mult
    causal_frac = (g.n_q_blocks + 1) / (2 * g.n_q_blocks)
    h, hd = max(cfg.n_heads, 1), cfg.resolved_head_dim
    attn_flops = (12.0 * cell.global_batch * cell.seq_len ** 2 * h * hd
                  * causal_frac * (1.5 if g.remat == "full" else 1.0)
                  ) if cfg.n_heads else 0.0
    embed_flops = 6.0 * tokens * d * cfg.vocab_size
    flops = param_flops + attn_flops + embed_flops

    # ---- memory (ideal-fusion altitude) ------------------------------------
    # weights traffic: every microbatch re-reads the (sharded) weights
    w_bytes = 2.0 * n_active / chips * 3 * g.microbatches  # fwd+bwd+remat
    act_row = tokens // n_data * d * 2  # one (B_loc, S, D) bf16 tensor
    resid_stack = L * act_row / g.microbatches
    act_traffic = L * act_row * (12 if g.remat == "full" else 9)
    logits_traffic = 6.0 * tokens // n_data * cfg.vocab_size \
        / (n_model if cfg.vocab_size % n_model == 0 else 1)
    bytes_hbm = w_bytes + act_traffic + logits_traffic

    # ---- collectives -------------------------------------------------------
    # TP all-reduce: 2 per layer fwd + 2 bwd, f32 on this backend
    tp_ar = L * 4 * (tokens // n_data) * d * 4
    # FSDP weight AG + grad RS per microbatch
    fsdp = 2.0 * n_active / n_model * 2 * g.microbatches / n_data
    moe = 0.0
    if cfg.n_experts:
        t_loc = tokens // n_data // g.microbatches
        if g.moe_impl == "ep_a2a":
            moe = (L * 4 * t_loc / n_model * cfg.experts_per_token
                   * d * 2 * g.microbatches * cfg.capacity_factor)
        else:  # pjit sort dispatch: measured ~full (T, D) f32 AR per layer
            moe = L * 4 * t_loc * d * 4 * g.microbatches
    bytes_coll = tp_ar + fsdp + moe

    # memory/collective quantities above are PER DEVICE; the shared backend
    # takes pod totals, so scale up and let it normalize back per chip.
    terms = TPU_ROOFLINE.roofline_terms(
        flops, bytes_hbm * chips, bytes_coll * chips, chips)

    # ---- activation live set ------------------------------------------------
    act_gib = (resid_stack + 2 * act_row / g.microbatches
               * (3 if g.remat == "dots" else 1)) / 2 ** 30
    return CostEstimate(terms.compute_s, terms.memory_s, terms.collective_s,
                        act_gib)


def enumerate_frontier(cfg: ModelConfig, cell: ShapeCell,
                       mesh_shape: Dict[str, int]
                       ) -> Tuple[List[ImplGenome], List[CostEstimate],
                                  np.ndarray]:
    """Exhaustive cheap evaluation + Pareto frontier (HALF step 1).

    The space is small enough to enumerate; the paper's evolutionary
    machinery matters when it is not — both share the Pareto selection.
    """
    genomes, costs = [], []
    for mb, qb, mi, rm in itertools.product(*SEARCH_SPACE.values()):
        if mi == "ep_a2a" and not cfg.n_experts:
            continue
        if cell.global_batch % mb:
            continue
        g = ImplGenome(mb, qb, mi, rm)
        genomes.append(g)
        costs.append(estimate_train_cell(cfg, cell, g, mesh_shape))
    pts = np.stack([c.vector() for c in costs])
    front = pareto_front(pts)
    return genomes, costs, front


def best_by_bound(genomes: List[ImplGenome], costs: List[CostEstimate],
                  front: np.ndarray, max_act_gib: float = 16.0
                  ) -> Tuple[ImplGenome, CostEstimate]:
    """Deployment selection (HALF step 2): min roofline bound on the
    frontier subject to the activation-memory constraint."""
    feas = [i for i in front if costs[i].act_gib <= max_act_gib] or \
        list(front)
    i = min(feas, key=lambda j: costs[j].bound_s)
    return genomes[i], costs[i]
