"""Named objective schema — the self-describing objective layer (DESIGN.md §10).

Before this module the objective matrix was an implicit convention: "7
floats in ``CHEAP_NAMES`` order" for whichever single ``CostBackend`` the
search happened to be configured with.  That convention cannot express the
paper's *holistic* story — the same population steered toward different
deployment targets and design goals (low-energy, low-power, high-throughput
variants of one search, §VI-B) or scored against several platforms at once
for cross-platform Pareto fronts.

Three pieces live here, deliberately dependency-free (``numpy`` only) so
that ``trainer``, ``cost_backend`` and ``objectives`` can all import them
without cycles:

* :class:`ObjectiveSchema` — a tuple of :class:`ObjectiveColumn` (name,
  cheap/expensive kind, platform tag); the objective matrix's column axis
  as data.  Backends carry one; ``PopulationArrays`` carries one;
  checkpoints persist and validate one.
* :class:`Constraints` — the paper's hard acceptance limits (90 %
  detection / 20 % false alarm) as one dataclass consumed by
  ``TrainResult.meets_constraints``, ``Candidate.meets_constraints``,
  ``PopulationArrays.feasible_mask`` and :class:`DesignGoal` (previously
  three duplicated pairs of default floats).
* :class:`DesignGoal` — a deployment-goal spec: which schema columns drive
  non-dominated sorting/selection and the final report, plus the
  constraint filter.  The paper's three presets ship (`low_energy`,
  `low_power`, `high_throughput`) next to the all-columns `balanced`
  default that reproduces the ungoaled engine bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

# Canonical objective names (paper §VI).  These are the single source of
# truth — ``repro.core.objectives`` re-exports them.
CHEAP_NAMES: Tuple[str, ...] = (
    "power_min_alpha_w", "power_max_alpha_w",
    "energy_min_alpha_j", "energy_max_alpha_j",
    "latency_min_alpha_s", "latency_max_alpha_s",
    "n_params",
)
EXPENSIVE_NAMES: Tuple[str, ...] = ("miss_rate", "false_alarm_rate")
ALL_NAMES: Tuple[str, ...] = CHEAP_NAMES + EXPENSIVE_NAMES

# Worst case per expensive column (all rates in [0, 1], minimized).  The
# pessimistic placeholder row for untrained/failed members is derived from
# the schema through :func:`pessimistic_expensive` — never hard-coded as a
# 2-vector — so a schema with a different expensive column set cannot
# silently corrupt the expensive matrix.
EXPENSIVE_WORST: Dict[str, float] = {
    "miss_rate": 1.0,
    "false_alarm_rate": 1.0,
}


def pessimistic_expensive(schema: "ObjectiveSchema") -> np.ndarray:
    """The worst-case expensive row for ``schema`` — one value per
    expensive column, in schema order.  Unknown columns default to 1.0
    (every expensive objective is a minimized rate)."""
    cols = [schema.columns[int(i)] for i in schema.expensive_indices()]
    return np.asarray([EXPENSIVE_WORST.get(c.name, 1.0) for c in cols],
                      dtype=np.float64)


# ---------------------------------------------------------------------------
# Constraints — the one copy of the paper's hard acceptance limits
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Constraints:
    """Hard acceptance limits on the expensive objectives (paper §VI)."""

    det_min: float = 0.90
    fa_max: float = 0.20

    @classmethod
    def coerce(cls, det_min: Union[None, float, "Constraints"] = None,
               fa_max: Optional[float] = None) -> "Constraints":
        """Accept a ready Constraints or the legacy (det_min, fa_max) pair
        (either may be None to keep the paper default)."""
        if isinstance(det_min, Constraints):
            return det_min
        base = cls()
        return cls(base.det_min if det_min is None else float(det_min),
                   base.fa_max if fa_max is None else float(fa_max))

    def ok(self, detection_rate: float, false_alarm_rate: float) -> bool:
        return detection_rate >= self.det_min \
            and false_alarm_rate <= self.fa_max

    def ok_rows(self, expensive: np.ndarray) -> np.ndarray:
        """Vectorized check over ``(N, 2)`` rows in objectives orientation
        (miss rate, false-alarm rate — both minimized)."""
        exp = np.atleast_2d(np.asarray(expensive, dtype=np.float64))
        return ((1.0 - exp[:, 0]) >= self.det_min) \
            & (exp[:, 1] <= self.fa_max)


DEFAULT_CONSTRAINTS = Constraints()


# ---------------------------------------------------------------------------
# ObjectiveSchema
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ObjectiveColumn:
    """One column of the objective matrix.

    All stored values are oriented for MINIMIZATION (callers negate
    higher-is-better metrics before they enter the matrix — detection rate
    is stored as miss rate, etc.), so orientation is a documentation field
    rather than a transform: it records what the minimized number means.
    """

    name: str             # e.g. "energy_max_alpha_j"
    kind: str             # "cheap" | "expensive"
    platform: str = ""    # backend/platform tag; "" = platform-agnostic

    def __post_init__(self):
        if self.kind not in ("cheap", "expensive"):
            raise ValueError(f"bad column kind {self.kind!r}")

    @property
    def qualified(self) -> str:
        """``platform:name`` (or bare name for platform-agnostic columns)."""
        return f"{self.platform}:{self.name}" if self.platform else self.name


@dataclasses.dataclass(frozen=True)
class ObjectiveSchema:
    """An ordered, named description of an objective matrix's columns.

    The schema is what lets every downstream consumer (non-dominated sort,
    environmental selection, solution reports, checkpoints) ask for columns
    by meaning — name, platform, cheap/expensive class — instead of
    hard-coding positions.
    """

    columns: Tuple[ObjectiveColumn, ...]

    def __post_init__(self):
        quals = [c.qualified for c in self.columns]
        if len(set(quals)) != len(quals):
            dupes = sorted({q for q in quals if quals.count(q) > 1})
            raise ValueError(f"duplicate objective columns: {dupes}")

    def __len__(self) -> int:
        return len(self.columns)

    def __iter__(self):
        return iter(self.columns)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.columns)

    @property
    def qualified_names(self) -> Tuple[str, ...]:
        return tuple(c.qualified for c in self.columns)

    @property
    def platforms(self) -> Tuple[str, ...]:
        """Distinct platform tags, in first-appearance order ('' excluded)."""
        seen: List[str] = []
        for c in self.columns:
            if c.platform and c.platform not in seen:
                seen.append(c.platform)
        return tuple(seen)

    # ----------------------------------------------------------- queries
    def index(self, name: str, platform: Optional[str] = None) -> int:
        """Position of one column.  ``name`` may be qualified
        (``platform:name``); an unqualified name must be unambiguous unless
        ``platform`` narrows it."""
        matches = self.indices(names=(name,), platform=platform)
        if len(matches) == 0:
            raise KeyError(f"no objective column {name!r}"
                           + (f" for platform {platform!r}" if platform
                              else "")
                           + f" (have: {list(self.qualified_names)})")
        if len(matches) > 1:
            raise KeyError(
                f"objective column {name!r} is ambiguous across platforms "
                f"{[self.columns[i].platform for i in matches]}; qualify it")
        return int(matches[0])

    def indices(self, names: Optional[Sequence[str]] = None,
                platform: Optional[Union[str, Sequence[str]]] = None,
                kind: Optional[str] = None) -> np.ndarray:
        """Positions of every column matching the filters, schema order.

        ``names`` entries may be bare (``energy_max_alpha_j``) or qualified
        (``fpga_zu:energy_max_alpha_j``); platform-agnostic columns match
        any platform filter (they mean the same thing everywhere).
        """
        if isinstance(platform, str):
            platform = (platform,)
        out = []
        for i, c in enumerate(self.columns):
            if kind is not None and c.kind != kind:
                continue
            if platform is not None and c.platform \
                    and c.platform not in platform:
                continue
            if names is not None \
                    and c.name not in names and c.qualified not in names:
                continue
            out.append(i)
        return np.asarray(out, dtype=np.int64)

    def cheap_indices(self) -> np.ndarray:
        return self.indices(kind="cheap")

    def expensive_indices(self) -> np.ndarray:
        return self.indices(kind="expensive")

    def platform_group(self, platform: str) -> np.ndarray:
        """Columns belonging to one platform plus the platform-agnostic
        (expensive) columns — a per-platform objective view."""
        if platform not in self.platforms:
            raise KeyError(f"no platform {platform!r} in schema "
                           f"(have: {list(self.platforms)})")
        return self.indices(platform=platform)

    def select(self, idx: Sequence[int]) -> "ObjectiveSchema":
        return ObjectiveSchema(tuple(self.columns[int(i)] for i in idx))

    # ------------------------------------------------------ constructors
    @staticmethod
    def cheap(platform: str = "") -> "ObjectiveSchema":
        """The 7 analytic objectives (``CHEAP_NAMES``) for one platform."""
        return ObjectiveSchema(tuple(
            ObjectiveColumn(n, "cheap", platform) for n in CHEAP_NAMES))

    @staticmethod
    def expensive() -> "ObjectiveSchema":
        return ObjectiveSchema(tuple(
            ObjectiveColumn(n, "expensive") for n in EXPENSIVE_NAMES))

    @staticmethod
    def concat(parts: Sequence["ObjectiveSchema"]) -> "ObjectiveSchema":
        return ObjectiveSchema(tuple(
            c for p in parts for c in p.columns))

    def with_expensive(self) -> "ObjectiveSchema":
        """This (cheap) schema + the expensive columns — the full objective
        matrix layout that selection operates on."""
        return ObjectiveSchema.concat([self, ObjectiveSchema.expensive()])

    # ------------------------------------------------------ serialization
    def to_json(self) -> List[Dict[str, str]]:
        return [{"name": c.name, "kind": c.kind, "platform": c.platform}
                for c in self.columns]

    @staticmethod
    def from_json(payload: Sequence[Dict[str, str]]) -> "ObjectiveSchema":
        return ObjectiveSchema(tuple(
            ObjectiveColumn(d["name"], d["kind"], d.get("platform", ""))
            for d in payload))


# The implicit pre-schema layout: 7 cheap columns of a single unnamed
# platform.  Used to adopt schema-less data (old checkpoints, raw arrays).
LEGACY_CHEAP_SCHEMA = ObjectiveSchema.cheap()


# ---------------------------------------------------------------------------
# DesignGoal
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DesignGoal:
    """A deployment goal: which objective columns steer the search.

    * ``objectives`` — cheap column names the goal cares about; ``()``
      means all of them.  Expensive columns (detection / false alarm)
      always participate in domination — dropping them would collapse the
      frontier's accuracy axis, which the paper never does.
    * ``platforms`` — restrict the goal to these platform tags; ``()``
      means every platform in the schema (cross-platform goal).
    * ``primary`` — the report-time ranking column
      (:meth:`~repro.core.evolution.EvolutionarySearch.select_solution`).
      With several platforms in scope the selector minimizes the *worst*
      (max) primary value across them — a robust cross-platform pick.
    * ``constraints`` — hard limits for the feasibility filter; ``None``
      inherits the search config's limits.
    """

    name: str
    objectives: Tuple[str, ...] = ()
    platforms: Tuple[str, ...] = ()
    primary: str = "energy_max_alpha_j"
    constraints: Optional[Constraints] = None

    def selection_indices(self, schema: ObjectiveSchema) -> np.ndarray:
        """Columns of the *full* (cheap + expensive) schema that drive
        non-dominated sorting and environmental selection."""
        # every requested name must match something — a typo'd objective
        # silently dropped would steer a whole search the wrong way
        for name in self.objectives:
            if len(schema.indices(names=(name,), kind="cheap")) == 0:
                raise KeyError(
                    f"goal {self.name!r}: objective {name!r} not in schema "
                    f"{list(schema.qualified_names)}")
        for platform in self.platforms:
            if platform not in schema.platforms:
                raise KeyError(
                    f"goal {self.name!r}: platform {platform!r} not in "
                    f"schema (have: {list(schema.platforms)})")
        cheap = schema.indices(
            names=self.objectives or None,
            platform=self.platforms or None, kind="cheap")
        if len(cheap) == 0:
            raise KeyError(
                f"goal {self.name!r} selects no cheap objective columns "
                f"from schema {list(schema.qualified_names)}")
        return np.concatenate([cheap, schema.expensive_indices()])

    def primary_indices(self, schema: ObjectiveSchema) -> np.ndarray:
        """The primary column, once per platform in scope."""
        idx = schema.indices(names=(self.primary,),
                             platform=self.platforms or None, kind="cheap")
        if len(idx) == 0:
            raise KeyError(f"goal {self.name!r}: primary objective "
                           f"{self.primary!r} not in schema")
        return idx

    def effective_constraints(self, fallback: Constraints) -> Constraints:
        return self.constraints if self.constraints is not None else fallback


# The paper's §VI-B deployment presets + the all-objectives default.
BALANCED = DesignGoal(name="balanced")
LOW_ENERGY = DesignGoal(
    name="low_energy",
    objectives=("energy_min_alpha_j", "energy_max_alpha_j", "n_params"),
    primary="energy_max_alpha_j")
LOW_POWER = DesignGoal(
    name="low_power",
    objectives=("power_min_alpha_w", "power_max_alpha_w", "n_params"),
    primary="power_min_alpha_w")
HIGH_THROUGHPUT = DesignGoal(
    name="high_throughput",
    objectives=("latency_min_alpha_s", "latency_max_alpha_s", "n_params"),
    primary="latency_max_alpha_s")

GOALS: Dict[str, DesignGoal] = {
    g.name: g for g in (BALANCED, LOW_ENERGY, LOW_POWER, HIGH_THROUGHPUT)}


def get_goal(spec: Union[str, DesignGoal]) -> DesignGoal:
    """Resolve a goal name or pass a ready :class:`DesignGoal` through."""
    if isinstance(spec, DesignGoal):
        return spec
    if spec in GOALS:
        return GOALS[spec]
    raise KeyError(f"unknown design goal {spec!r} "
                   f"(presets: {sorted(GOALS)})")
