"""Optimizers (pure JAX — no optax on the box)."""
from repro.optim.adamw import adafactor, adamw, apply_updates, clip_by_global_norm  # noqa: F401
from repro.optim.schedules import constant, cosine_schedule, linear_warmup_cosine  # noqa: F401
