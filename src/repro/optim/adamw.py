"""AdamW and Adafactor as (init, update) pairs over arbitrary pytrees.

Interface mirrors optax: ``opt = adamw(lr); state = opt.init(params);
updates, state = opt.update(grads, state, params); params =
apply_updates(params, updates)``.

Adafactor (factored second moment, no first moment by default) is provided
for the 1T-parameter configs where AdamW's 12 bytes/param of state cannot fit
the pod (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype),
                                  params, updates)


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          state_dtype: jnp.dtype = jnp.float32) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree_util.tree_map(zeros, params),
                          v=jax.tree_util.tree_map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(state_dtype)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mhat = m_new / bc1
            vhat = v_new / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * p.astype(state_dtype))
            return u, m_new, v_new

        flat = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
        updates = jax.tree_util.tree_map(lambda t: t[0], flat,
                                         is_leaf=lambda t: isinstance(t, tuple))
        m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
        return updates, AdamWState(step=step, m=m, v=v)

    return Optimizer(init=init, update=update)


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any   # row second-moment (or full v for <2D leaves)
    vc: Any   # col second-moment (None marker: zeros(0) for <2D leaves)


def adafactor(lr: Callable | float, *, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern '18), beta1=0.

    For >=2-D leaves the second moment is stored as a row vector + column
    vector over the trailing two dims: O(n+m) state instead of O(n*m).
    """
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def factored(p):
        return p.ndim >= 2

    def init(params):
        def vr_init(p):
            if factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            if factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((0,), jnp.float32)

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            vr=jax.tree_util.tree_map(vr_init, params),
            vc=jax.tree_util.tree_map(vc_init, params),
        )

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                vr_new = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc_new = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                r = vr_new / jnp.maximum(
                    jnp.mean(vr_new, axis=-1, keepdims=True), eps)
                prec = (r[..., None] * vc_new[..., None, :])
                u = g * jax.lax.rsqrt(jnp.maximum(prec, eps))
            else:
                vr_new = beta2 * vr + (1 - beta2) * g2
                vc_new = vc
                u = g * jax.lax.rsqrt(jnp.maximum(vr_new, eps))
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr_t * (u + weight_decay * p.astype(jnp.float32))
            return u, vr_new, vc_new

        flat = jax.tree_util.tree_map(upd, grads, state.vr, state.vc, params)
        is_t = lambda t_: isinstance(t_, tuple)
        updates = jax.tree_util.tree_map(lambda x: x[0], flat, is_leaf=is_t)
        vr = jax.tree_util.tree_map(lambda x: x[1], flat, is_leaf=is_t)
        vc = jax.tree_util.tree_map(lambda x: x[2], flat, is_leaf=is_t)
        return updates, AdafactorState(step=step, vr=vr, vc=vc)

    return Optimizer(init=init, update=update)
