"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    def schedule(step):
        return jnp.asarray(value, jnp.float32)
    return schedule


def cosine_schedule(peak: float, total_steps: int, final_frac: float = 0.1):
    def schedule(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return peak * (final_frac + (1 - final_frac) * cos)
    return schedule


def linear_warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(peak, max(total_steps - warmup_steps, 1), final_frac)
    def schedule(step):
        warm = peak * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return schedule
