"""Public op: SSD chunked scan (Pallas on TPU, chunked-jnp / oracle elsewhere)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def ssd(x, dt, a_neg, b_mat, c_mat, *, chunk: int = 256,
        impl: str = "pallas", interpret: bool = True) -> jnp.ndarray:
    """Mamba-2 SSD. x: (B,L,H,P), dt: (B,L,H), a_neg: (H,), b/c: (B,L,G,N).

    Returns y (B,L,H,P).  ``impl="ref"`` runs the naive recurrence oracle;
    the chunked jnp path used by the models lives in repro.models.mamba2.
    """
    if impl == "ref":
        return ssd_ref(x, dt, a_neg, b_mat, c_mat)[0]
    return ssd_pallas(x, dt, a_neg, b_mat, c_mat, chunk=chunk,
                      interpret=interpret)
