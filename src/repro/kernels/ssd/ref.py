"""Oracle: naive per-step SSD recurrence (trivially correct, O(L) steps).

h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) ⊗ B_t
y_t = C_t · h_t
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, a_neg: jnp.ndarray,
            b_mat: jnp.ndarray, c_mat: jnp.ndarray,
            h0: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,L,H,P), dt: (B,L,H), a_neg: (H,) (negative), b/c: (B,L,G,N).

    Returns (y (B,L,H,P), final state (B,H,N,P))."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    bh = jnp.repeat(b_mat, rep, axis=2).astype(jnp.float32)  # (B,L,H,N)
    ch = jnp.repeat(c_mat, rep, axis=2).astype(jnp.float32)
    dtx = (x.astype(jnp.float32) * dt[..., None])

    def step(state, inputs):
        dtx_t, loga_t, b_t, c_t = inputs
        decay = jnp.exp(loga_t)[..., None, None]            # (B,H,1,1)
        state = state * decay + jnp.einsum("bhn,bhp->bhnp", b_t, dtx_t)
        y = jnp.einsum("bhn,bhnp->bhp", c_t, state)
        return state, y

    loga = dt * a_neg
    xs = (dtx.swapaxes(0, 1), loga.swapaxes(0, 1).astype(jnp.float32),
          bh.swapaxes(0, 1), ch.swapaxes(0, 1))
    state0 = jnp.zeros((bsz, h, n, p), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), state
