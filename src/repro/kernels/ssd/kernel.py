"""Pallas TPU kernel: Mamba-2 SSD chunked scan (forward).

Grid ``(B, H, n_chunks)`` — the chunk axis is innermost/sequential, so the
(N, P) inter-chunk state lives in VMEM scratch across chunk steps (the same
sequential-grid carry pattern as the flash-attention kernel's softmax state).

Per chunk (Q = chunk length):
  intra:  M = tril(C B^T ⊙ exp(Δcum)) ; Y += M @ (dt·X)      (MXU: Q×N×Q, Q×Q×P)
  inter:  Y += exp(cum) * (C @ state)                        (MXU: Q×N×P)
  state:  state = exp(total) * state + (w·B)^T @ (dt·X)      (MXU: N×Q×P)

VMEM per step (f32): x/b/c/out chunks Q*(2N+2P) + scores Q² + state N*P.
Q = 256, N = 128, P = 64 → ~0.7 MB.

B/C head-group mapping (GQA-style groups) is done by the BlockSpec index map
(``h // rep``), mirroring the flash kernel's KV-head mapping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, loga_ref, b_ref, c_ref, o_ref, state_scr, *, q: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xc = x_ref[0, 0].astype(jnp.float32)        # (Q, P)  — already dt-scaled
    lac = loga_ref[0, 0].astype(jnp.float32)    # (Q,)
    bc = b_ref[0, 0].astype(jnp.float32)        # (Q, N)
    cc = c_ref[0, 0].astype(jnp.float32)        # (Q, N)

    cum = jnp.cumsum(lac)                       # (Q,)
    state = state_scr[...]                      # (N, P)

    # inter-chunk: carried state contribution
    y_inter = jax.lax.dot_general(cc, state, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[:, None]

    # intra-chunk: masked decay-weighted attention-like form
    scores = jax.lax.dot_general(cc, bc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    dd = cum[:, None] - cum[None, :]
    t_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    s_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    m = jnp.where(t_pos >= s_pos, scores * jnp.exp(dd), 0.0)
    y_intra = jax.lax.dot_general(m, xc, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    o_ref[0, 0] = (y_inter + y_intra).astype(o_ref.dtype)

    # state update
    total = cum[-1]
    w = jnp.exp(total - cum)                    # (Q,)
    state_scr[...] = state * jnp.exp(total) + jax.lax.dot_general(
        bc * w[:, None], xc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def ssd_pallas(
    x: jnp.ndarray,        # (B, L, H, P)
    dt: jnp.ndarray,       # (B, L, H) positive
    a_neg: jnp.ndarray,    # (H,) negative
    b_mat: jnp.ndarray,    # (B, L, G, N)
    c_mat: jnp.ndarray,    # (B, L, G, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    q = min(chunk, l)
    assert l % q == 0, "pad L to a chunk multiple"
    nc = l // q

    dtx = (x.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    loga = (dt * a_neg).astype(jnp.float32)     # (B, L, H)

    # head-major layouts
    xt = dtx.swapaxes(1, 2)                     # (B, H, L, P)
    lat = loga.swapaxes(1, 2)                   # (B, H, L)
    bt = b_mat.swapaxes(1, 2)                   # (B, G, L, N)
    ct = c_mat.swapaxes(1, 2)

    out = pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, q), lambda b_, h_, c_: (b_, h_, c_)),
            pl.BlockSpec((1, 1, q, n),
                         lambda b_, h_, c_: (b_, h_ // rep, c_, 0)),
            pl.BlockSpec((1, 1, q, n),
                         lambda b_, h_, c_: (b_, h_ // rep, c_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, p),
                               lambda b_, h_, c_: (b_, h_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, l, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xt, lat, bt, ct)
    return out.swapaxes(1, 2)
