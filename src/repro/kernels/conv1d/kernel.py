"""Pallas TPU kernel: fused depthwise-separable 1D conv + bias + ReLU.

TPU adaptation of HALF's dataflow conv engine (DESIGN.md §2): instead of an
FPGA shift-register pipeline, the record is tiled into VMEM and the pointwise
(1x1) stage is fed to the MXU as an (L_out, C_in) x (C_in, BCO) matmul — the
depthwise stage is a K-tap fused multiply-add chain on the VPU.

Grid: ``(B, n_cout_blocks)`` — output-channel blocks are the innermost
(fastest) axis, so the depthwise result, which is independent of the output
channel, is computed once per record at ``j == 0`` into a VMEM scratch and
reused for the remaining C_out blocks (the TPU grid is sequential).

VMEM budget per step (f32): x tile L*C_in + scratch L_out*C_in
+ pw C_in*BCO + out L_out*BCO.  For the ECG search space (L <= 3750,
C <= 32, BCO = 128) that is < 2.5 MB — comfortably inside one core's VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BCO = 128


def _kernel(x_ref, dw_ref, pw_ref, b_ref, o_ref, dw_scratch, *,
            stride: int, relu: bool, l_out: int):
    j = pl.program_id(1)

    # depthwise stage: compute once per record (j == 0), reuse afterwards
    @pl.when(j == 0)
    def _():
        xv = x_ref[0]                       # (L, C_in) in VMEM
        k = dw_ref.shape[0]
        c_in = xv.shape[1]
        acc = jnp.zeros((l_out, c_in), jnp.float32)
        for i in range(k):                  # K-tap FMA chain (VPU)
            sl = jax.lax.slice(xv, (i, 0),
                               (i + (l_out - 1) * stride + 1, c_in),
                               (stride, 1))
            acc = acc + sl.astype(jnp.float32) * dw_ref[i].astype(jnp.float32)
        dw_scratch[...] = acc

    # pointwise stage: (L_out, C_in) @ (C_in, BCO) on the MXU
    y = jax.lax.dot_general(
        dw_scratch[...], pw_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y = y + b_ref[...].astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[0] = y.astype(o_ref.dtype)


def dwsep_conv1d_pallas(x: jnp.ndarray, dw: jnp.ndarray, pw: jnp.ndarray,
                        b: jnp.ndarray, *, stride: int = 1, relu: bool = True,
                        block_cout: int = DEFAULT_BCO,
                        interpret: bool = False) -> jnp.ndarray:
    bsz, l, c_in = x.shape
    k = dw.shape[0]
    c_out = pw.shape[1]
    l_out = (l - k) // stride + 1
    bco = min(block_cout, c_out)
    n_co = -(-c_out // bco)
    pad_co = n_co * bco - c_out
    if pad_co:
        pw = jnp.pad(pw, ((0, 0), (0, pad_co)))
        b = jnp.pad(b, (0, pad_co))

    out = pl.pallas_call(
        functools.partial(_kernel, stride=stride, relu=relu, l_out=l_out),
        grid=(bsz, n_co),
        in_specs=[
            pl.BlockSpec((1, l, c_in), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((k, c_in), lambda i, j: (0, 0)),
            pl.BlockSpec((c_in, bco), lambda i, j: (0, j)),
            pl.BlockSpec((bco,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, l_out, bco), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, l_out, n_co * bco), x.dtype),
        scratch_shapes=[pltpu.VMEM((l_out, c_in), jnp.float32)],
        interpret=interpret,
    )(x, dw, pw, b)
    return out[:, :, :c_out]
