"""Public op: depthwise-separable conv1d (Pallas on TPU, oracle elsewhere)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.conv1d.kernel import dwsep_conv1d_pallas
from repro.kernels.conv1d.ref import dwsep_conv1d_ref


@functools.partial(jax.jit, static_argnames=("stride", "relu", "impl",
                                             "interpret"))
def dwsep_conv1d(x: jnp.ndarray, dw: jnp.ndarray, pw: jnp.ndarray,
                 b: jnp.ndarray, *, stride: int = 1, relu: bool = True,
                 impl: str = "pallas", interpret: bool = True) -> jnp.ndarray:
    """Fused depthwise-separable 1D convolution.

    Args:
      x:  (B, L, C_in); dw: (K, C_in); pw: (C_in, C_out); b: (C_out,).
      impl: "pallas" (TPU kernel; interpret=True executes it on CPU) or
        "ref" (pure jnp oracle).
    """
    if x.ndim != 3 or dw.ndim != 2 or pw.ndim != 2:
        raise ValueError("bad ranks")
    if dw.shape[1] != x.shape[2] or pw.shape[0] != x.shape[2] \
            or b.shape[0] != pw.shape[1]:
        raise ValueError("inconsistent channel dims")
    if impl == "ref":
        return dwsep_conv1d_ref(x, dw, pw, b, stride=stride, relu=relu)
    return dwsep_conv1d_pallas(x, dw, pw, b, stride=stride, relu=relu,
                               interpret=interpret)
