"""Oracle for the depthwise-separable 1D convolution (HALF's hot spot)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dwsep_conv1d_ref(x: jnp.ndarray, dw: jnp.ndarray, pw: jnp.ndarray,
                     b: jnp.ndarray, *, stride: int = 1,
                     relu: bool = True) -> jnp.ndarray:
    """x: (B, L, C_in), dw: (K, C_in), pw: (C_in, C_out), b: (C_out,).

    VALID padding: L_out = (L - K) // stride + 1.
    """
    k = dw.shape[0]
    l_out = (x.shape[1] - k) // stride + 1
    acc = jnp.zeros((x.shape[0], l_out, x.shape[2]), jnp.float32)
    for i in range(k):
        sl = jax.lax.slice_in_dim(x, i, i + (l_out - 1) * stride + 1,
                                  stride, 1)
        acc = acc + sl.astype(jnp.float32) * dw[i].astype(jnp.float32)
    y = acc @ pw.astype(jnp.float32) + b.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)
