"""Pallas TPU kernel: grouped matmul — the MoE expert-FFN contraction.

``out[e] = x[e] @ w[e]`` for E experts with a fixed per-expert capacity.
Grid ``(E, n_c, n_f, n_d)`` with the contraction (D) axis innermost and a
f32 VMEM accumulator across D steps; tiles are MXU-aligned (128 lanes).

This is the contraction ``repro.models.moe.moe_block`` spells as
``einsum('ecd,edf->ecf')``; on TPU the kernel replaces that einsum after the
sort-based dispatch has built the (E, C, D) buffer.

VMEM per step (bf16 in, f32 acc): x BC*BD + w BD*BF + acc BC*BF.
BC = BF = BD = 512 → 2.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_scr, *, n_d: int):
    d_idx = pl.program_id(3)

    @pl.when(d_idx == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(d_idx == n_d - 1)
    def _finish():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def gmm_pallas(x: jnp.ndarray, w: jnp.ndarray, *, block_c: int = 512,
               block_f: int = 512, block_d: int = 512,
               interpret: bool = False) -> jnp.ndarray:
    e, c, d = x.shape
    e2, d2, f = w.shape
    assert e == e2 and d == d2
    bc, bf, bd = min(block_c, c), min(block_f, f), min(block_d, d)
    assert c % bc == 0 and f % bf == 0 and d % bd == 0, \
        "pad capacity/width to block multiples"
    grid = (e, c // bc, f // bf, d // bd)

    return pl.pallas_call(
        functools.partial(_kernel, n_d=d // bd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e_, i, j, k_: (e_, i, k_)),
            pl.BlockSpec((1, bd, bf), lambda e_, i, j, k_: (e_, k_, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e_, i, j, k_: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
