"""Public op: grouped matmul for MoE expert FFNs."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_gmm.kernel import gmm_pallas
from repro.kernels.moe_gmm.ref import gmm_ref


@functools.partial(jax.jit, static_argnames=("impl", "interpret", "block_c",
                                             "block_f", "block_d"))
def gmm(x, w, *, impl: str = "pallas", interpret: bool = True,
        block_c: int = 512, block_f: int = 512, block_d: int = 512
        ) -> jnp.ndarray:
    """Grouped matmul: (E, C, D) @ (E, D, F) -> (E, C, F)."""
    if impl == "ref":
        return gmm_ref(x, w)
    return gmm_pallas(x, w, block_c=block_c, block_f=block_f,
                      block_d=block_d, interpret=interpret)
