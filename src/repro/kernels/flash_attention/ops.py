"""Public op: flash attention (Pallas on TPU, chunked-jnp / oracle elsewhere)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "impl", "interpret",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, impl: str = "pallas",
                    interpret: bool = True, block_q: int = 512,
                    block_k: int = 512) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KVH, hd)."""
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=interpret)
