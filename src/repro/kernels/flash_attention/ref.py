"""Oracle: full-softmax attention (materializes scores — small shapes only)."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KVH, hd) with H % KVH == 0."""
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qf = q.astype(jnp.float32).reshape(b, sq, kvh, rep, hd)
    s = jnp.einsum("bqgrd,bkgd->bqgrk", qf, k.astype(jnp.float32))
    s = s / (hd ** 0.5)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqgrk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)
