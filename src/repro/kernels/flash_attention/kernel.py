"""Pallas TPU kernel: causal GQA flash attention (forward).

Canonical blocked online-softmax: grid ``(B, H, n_q, n_kv)`` with the KV
block axis innermost (sequential on TPU), so the running max / denominator /
accumulator live in VMEM scratch across KV steps and the output block is
written once at the last KV step.

* GQA: the K/V BlockSpec index maps head ``h`` to KV head ``h // rep`` —
  no repeated KV materialization.
* Causality: blocks entirely above the diagonal are skipped via ``pl.when``
  (no MXU work), the diagonal block is masked elementwise.

VMEM per step (f32): q BQ*hd + k/v 2*BK*hd + acc BQ*hd + scores BQ*BK.
BQ = BK = 512, hd = 128 → ~2.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, n_kv: int, causal: bool, scale: float):
    i = pl.program_id(2)   # q block
    j = pl.program_id(3)   # kv block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip fully-masked blocks (strictly above the causal diagonal)
    if causal:
        run_pred = j * bk < (i + 1) * bq
    else:
        run_pred = jnp.bool_(True)

    @pl.when(run_pred)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,          # (B, Sq, H, hd)
    k: jnp.ndarray,          # (B, Sk, KVH, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0
    rep = h // kvh
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, "pad seq to block multiples"
    n_q, n_kv = sq // bq, sk // bk

    # (B, S, H, hd) -> (B, H, S, hd) for head-major blocking
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, n_kv=n_kv, causal=causal,
                          scale=1.0 / (hd ** 0.5)),
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.swapaxes(1, 2)
