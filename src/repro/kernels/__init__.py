"""Pallas TPU kernels for the compute hot-spots.

Each subpackage: ``kernel.py`` (pl.pallas_call + BlockSpec VMEM tiling),
``ops.py`` (jit'd public wrapper with an interpret switch), ``ref.py``
(pure-jnp oracle).  On this CPU container kernels run interpret=True;
on TPU the same pallas_call lowers natively.
"""
