"""Oracle: single-token GQA attention over a (padded) KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         kv_len: jnp.ndarray) -> jnp.ndarray:
    """q: (B, H, hd); k/v: (B, S, KVH, hd); kv_len: (B,) valid prefix.

    Returns (B, H, hd)."""
    b, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, kvh, rep, hd).astype(jnp.float32) / (hd ** 0.5)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qg, k.astype(jnp.float32))
    mask = jnp.arange(s)[None, :] < kv_len[:, None]          # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p, v.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)
