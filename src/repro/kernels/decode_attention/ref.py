"""Oracle: single-token GQA attention over a (padded) KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         kv_len: jnp.ndarray) -> jnp.ndarray:
    """q: (B, H, hd); k/v: (B, S, KVH, hd); kv_len: (B,) valid prefix.

    Returns (B, H, hd)."""
    b, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, kvh, rep, hd).astype(jnp.float32) / (hd ** 0.5)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qg, k.astype(jnp.float32))
    mask = jnp.arange(s)[None, :] < kv_len[:, None]          # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p, v.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)


def gather_paged_kv(k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                    tables: jnp.ndarray):
    """Materialize each row's logical cache from the block pool.

    k/v_pages: (P, BS, KVH, hd) global pools; tables: (B, NB) int32 block
    tables (entries >= P are unallocated sentinels — clamped, then masked
    by ``kv_len`` downstream).  Returns dense (B, NB*BS, KVH, hd) views.
    """
    p, bs, kvh, hd = k_pages.shape
    b, nb = tables.shape
    tbl = jnp.minimum(tables, p - 1)
    k = k_pages[tbl].reshape(b, nb * bs, kvh, hd)
    v = v_pages[tbl].reshape(b, nb * bs, kvh, hd)
    return k, v


def paged_decode_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                               v_pages: jnp.ndarray, tables: jnp.ndarray,
                               kv_len: jnp.ndarray) -> jnp.ndarray:
    """Dense-gather oracle for paged decode attention.

    q: (B, H, hd); k/v_pages: (P, BS, KVH, hd); tables: (B, NB);
    kv_len: (B,) valid logical prefix.  Gathers each row's blocks into a
    contiguous cache and runs :func:`decode_attention_ref` — the parity
    anchor for both the paged Pallas kernel and the chunked fast path.
    """
    k, v = gather_paged_kv(k_pages, v_pages, tables)
    return decode_attention_ref(q, k, v, kv_len)
