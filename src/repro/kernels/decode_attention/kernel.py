"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

The serving hot spot: one query per sequence, KV cache of up to 512k
tokens.  Grid ``(B, KVH, n_kv)`` — each step processes one KV head's block
for all its ``rep`` grouped query heads at once (an MXU-friendly
(rep, hd) x (hd, BK) contraction), with the online-softmax state in VMEM
scratch across the sequential KV-block axis.

The valid cache length arrives as a scalar-prefetch operand
(``PrefetchScalarGridSpec``), so fully-invalid blocks are skipped via
``pl.when`` — a request at position 1k in a 512k cache does ~0.2 % of the
worst-case work (the production analogue of paged attention block tables).

VMEM per step (f32): q rep*hd + k/v 2*BK*hd + acc rep*hd + scores rep*BK.
rep = 8, BK = 512, hd = 128 → ~0.8 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bk: int, n_kv: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[b]

    @pl.when(j * bk < kv_len)   # skip fully-invalid cache blocks
    def _compute():
        hd = q_ref.shape[-1]
        q = q_ref[0, 0].astype(jnp.float32) / (hd ** 0.5)   # (rep, hd)
        k = k_ref[0, 0].astype(jnp.float32)                 # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jnp.ndarray,        # (B, H, hd)
    k: jnp.ndarray,        # (B, S, KVH, hd)
    v: jnp.ndarray,
    kv_len: jnp.ndarray,   # (B,) int32
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    bk = min(block_k, s)
    assert s % bk == 0, "pad the cache to a block multiple"
    n_kv = s // bk

    qg = q.reshape(b, kvh, rep, hd)
    kt = k.swapaxes(1, 2)          # (B, KVH, S, hd)
    vt = v.swapaxes(1, 2)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd),
                         lambda b_, g_, j, lens: (b_, g_, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, g_, j, lens: (b_, g_, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, g_, j, lens: (b_, g_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd),
                               lambda b_, g_, j, lens: (b_, g_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, n_kv=n_kv),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, rep, hd), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, kt, vt)
    return out.reshape(b, h, hd)


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, bk: int, n_kv: int):
    # The block table is consumed entirely inside the BlockSpec index maps
    # (scalar prefetch steers which pool page lands in VMEM); the online-
    # softmax body is identical to the dense kernel's.
    del tbl_ref
    _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            bk=bk, n_kv=n_kv)


def paged_decode_attention_pallas(
    q: jnp.ndarray,        # (B, H, hd)
    k_pages: jnp.ndarray,  # (P, BS, KVH, hd) global block pool
    v_pages: jnp.ndarray,
    tables: jnp.ndarray,   # (B, NB) int32 per-row block tables
    kv_len: jnp.ndarray,   # (B,) int32 valid logical prefix
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Paged decode attention: gather K/V pages via block tables.

    Both the table and the lengths ride as scalar-prefetch operands, so
    the K/V BlockSpec index map reads ``tables[b, j]`` to pull the j-th
    logical block of row ``b`` straight from the pool — no host gather,
    no per-row dense cache.  Unallocated table entries (sentinel >= P)
    are clamped to a valid page and masked by ``kv_len`` (positions past
    the valid prefix score ``NEG_INF`` exactly as in the dense kernel);
    fully-invalid logical blocks are skipped via ``pl.when``.
    """
    b, h, hd = q.shape
    n_pages, bs, kvh = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    nb = tables.shape[1]
    rep = h // kvh

    qg = q.reshape(b, kvh, rep, hd)
    kt = k_pages.swapaxes(1, 2)    # (P, KVH, BS, hd)
    vt = v_pages.swapaxes(1, 2)
    tbl = jnp.minimum(tables, n_pages - 1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, nb),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd),
                         lambda b_, g_, j, tbl, lens: (b_, g_, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd),
                         lambda b_, g_, j, tbl, lens: (tbl[b_, j], g_, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd),
                         lambda b_, g_, j, tbl, lens: (tbl[b_, j], g_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd),
                               lambda b_, g_, j, tbl, lens: (b_, g_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, bk=bs, n_kv=nb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, rep, hd), q.dtype),
        interpret=interpret,
    )(tbl, kv_len.astype(jnp.int32), qg, kt, vt)
    return out.reshape(b, h, hd)
