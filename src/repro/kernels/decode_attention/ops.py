"""Public op: decode attention (Pallas on TPU, oracle elsewhere)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas, paged_decode_attention_pallas)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, gather_paged_kv, paged_decode_attention_ref)

NEG_INF = -1e30


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> auto: compiled on TPU, interpreter everywhere else.

    ``jax.default_backend()`` is static at trace time, so this is safe to
    call under ``jit`` (the choice is baked into the compiled program).
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("impl", "interpret", "block_k"))
def decode_attention(q, k, v, kv_len, *, impl: str = "pallas",
                     interpret: Optional[bool] = None, block_k: int = 512
                     ) -> jnp.ndarray:
    """Single-token GQA attention. q: (B,H,hd); k/v: (B,S,KVH,hd);
    kv_len: (B,) valid prefix lengths.

    ``interpret=None`` auto-selects: the compiled Pallas kernel on TPU,
    interpret mode elsewhere (so CPU/GPU callers never hit the Mosaic
    lowering path by accident, and TPU callers never silently run the
    interpreter)."""
    if impl == "ref":
        return decode_attention_ref(q, k, v, kv_len)
    return decode_attention_pallas(q, k, v, kv_len, block_k=block_k,
                                   interpret=resolve_interpret(interpret))


def paged_decode_attention_chunked(q, k_pages, v_pages, tables, kv_len,
                                   *, pages_per_chunk: int = 8
                                   ) -> jnp.ndarray:
    """Non-TPU fast path: online softmax over page-table chunks.

    Never materializes the full (B, NB*BS, ...) gathered cache — each
    ``lax.scan`` step gathers ``pages_per_chunk`` pages per row and folds
    them into running (m, l, acc) online-softmax state, so peak memory is
    bounded by the chunk, not the logical context.  Matches the paged
    reference to float tolerance (the accumulation order differs, so it is
    deliberately *not* the engine's bit-parity path).
    """
    b, h, hd = q.shape
    n_pages, bs, kvh = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    nb = tables.shape[1]
    rep = h // kvh
    ppc = min(pages_per_chunk, nb)
    pad = (-nb) % ppc
    tbl = jnp.minimum(tables, n_pages - 1).astype(jnp.int32)
    if pad:
        # Sentinel-pad to a chunk multiple; padded pages sit past every
        # row's kv_len and are masked below.
        tbl = jnp.concatenate(
            [tbl, jnp.zeros((b, pad), jnp.int32)], axis=1)
    n_chunks = tbl.shape[1] // ppc
    chunks = tbl.reshape(b, n_chunks, ppc).swapaxes(0, 1)   # (NC, B, PPC)

    qg = q.reshape(b, kvh, rep, hd).astype(jnp.float32) / (hd ** 0.5)

    def body(carry, inp):
        m, l, acc = carry
        c, tbl_c = inp                                       # (B, PPC)
        kc = k_pages[tbl_c].astype(jnp.float32)              # (B,PPC,BS,KVH,hd)
        vc = v_pages[tbl_c].astype(jnp.float32)
        kc = kc.reshape(b, ppc * bs, kvh, hd)
        vc = vc.reshape(b, ppc * bs, kvh, hd)
        s = jnp.einsum("bgrd,bcgd->bgrc", qg, kc)            # (B,KVH,rep,C)
        pos = c * (ppc * bs) + jnp.arange(ppc * bs)          # logical positions
        mask = pos[None, :] < kv_len[:, None]                # (B, C)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bgrc,bcgd->bgrd", p, vc)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, kvh, rep), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, rep), jnp.float32),
            jnp.zeros((b, kvh, rep, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init,
                                  (jnp.arange(n_chunks), chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, hd).astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("impl", "interpret", "pages_per_chunk"))
def paged_decode_attention(q, k_pages, v_pages, tables, kv_len, *,
                           impl: str = "auto",
                           interpret: Optional[bool] = None,
                           pages_per_chunk: int = 8) -> jnp.ndarray:
    """Paged single-token GQA attention over a global block pool.

    q: (B,H,hd); k/v_pages: (P,BS,KVH,hd); tables: (B,NB) int32 block
    tables (sentinel >= P marks unallocated slots); kv_len: (B,) valid
    logical prefix lengths.

    ``impl``: "auto" runs the Pallas kernel when it would compile (TPU, or
    an explicit ``interpret=True``... the auto default keeps TPU on the
    compiled kernel) and the chunked online-softmax path elsewhere;
    "pallas" forces the kernel (interpret auto-resolved); "chunked" forces
    the scan path; "ref" is the dense-gather oracle.
    """
    if impl == "ref":
        return paged_decode_attention_ref(q, k_pages, v_pages, tables,
                                          kv_len)
    if impl == "chunked":
        return paged_decode_attention_chunked(
            q, k_pages, v_pages, tables, kv_len,
            pages_per_chunk=pages_per_chunk)
    itp = resolve_interpret(interpret)
    if impl == "pallas" or not itp:
        return paged_decode_attention_pallas(q, k_pages, v_pages, tables,
                                             kv_len, interpret=itp)
    return paged_decode_attention_chunked(
        q, k_pages, v_pages, tables, kv_len,
        pages_per_chunk=pages_per_chunk)


__all__ = [
    "decode_attention",
    "paged_decode_attention",
    "paged_decode_attention_chunked",
    "paged_decode_attention_ref",
    "gather_paged_kv",
    "resolve_interpret",
]
