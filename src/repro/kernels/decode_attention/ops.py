"""Public op: decode attention (Pallas on TPU, oracle elsewhere)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("impl", "interpret", "block_k"))
def decode_attention(q, k, v, kv_len, *, impl: str = "pallas",
                     interpret: bool = True, block_k: int = 512
                     ) -> jnp.ndarray:
    """Single-token GQA attention. q: (B,H,hd); k/v: (B,S,KVH,hd);
    kv_len: (B,) valid prefix lengths."""
    if impl == "ref":
        return decode_attention_ref(q, k, v, kv_len)
    return decode_attention_pallas(q, k, v, kv_len, block_k=block_k,
                                   interpret=interpret)
